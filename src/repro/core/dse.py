"""Automated design-space exploration (paper §IV-E, Fig 13).

Two DSE loops live here:

**run_dse** explores profiling configurations — storage class
(register-like shallow rings, BRAM-like deep rings, hybrid) x DRAM dump
ratio (0/25/50/75%) — and scores each on the paper's three metrics:

  1) resource overhead      on-device state bytes + extra HLO equations
                            (weighted, relative to the base program),
  2) DRAM bandwidth         measured offloaded bytes / profiled span,
  3) latency impact         measured wall-time of the instrumented step
                            relative to the unprobed step (Fmax analogue).

It returns all points plus the Pareto-optimal subset. Incremental
re-instrumentation (cached trace/hierarchy) is what makes the sweep
cheap — each point only rebuilds the probe layer, like the paper's
incremental synthesis.

**DSEEngine** closes the paper's second loop: probe telemetry driving
*kernel-configuration* search under device resource budgets. Given a
:class:`SearchSpace` (tile sizes / pipeline depth per Pallas kernel) it

  1) enumerates candidate configs,
  2) prunes statically with the cost model against a
     :class:`~repro.core.costmodel.DeviceBudget` (VMEM bytes, HBM
     traffic, FLOPs — the LUT/FF/BRAM-constraint analogue),
  3) measures survivors with ``ProbeSession`` cycle telemetry under
     successive halving (cheap configs get few steps, finalists many),
  4) memoizes every measurement in the on-disk
     :class:`~repro.core.incremental.EvalCache` keyed by (kernel id,
     config, lowered-IR hash, device kind) — re-running after an
     unrelated edit re-measures nothing.
"""
from __future__ import annotations

import itertools
import math
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.buffer import state_bytes
from repro.core.costmodel import (CLOCK_HZ, DeviceBudget, KernelResources,
                                  jaxpr_kernel_resources)
from repro.core.incremental import (EvalCache, device_kind,
                                    fingerprint_closed)
from repro.core.instrument import decode_record
from repro.core.pragma import ProbeConfig, probe

STORAGE_DEPTH = {"registers": 4, "hybrid": 16, "bram": 64}


@dataclass
class DSEPoint:
    storage: str
    depth: int
    offload_ratio: float
    n_probes: int
    state_bytes: int
    extra_eqns: int
    dram_bytes: int
    dram_bandwidth_bps: float        # modeled at the TPU clock
    latency_overhead: float          # measured wall-time ratio - 1
    weighted_resource: float

    def dominates(self, o: "DSEPoint") -> bool:
        a = (self.weighted_resource, self.dram_bandwidth_bps,
             self.latency_overhead)
        b = (o.weighted_resource, o.dram_bandwidth_bps, o.latency_overhead)
        return all(x <= y for x, y in zip(a, b)) and a != b


@dataclass
class DSEResult:
    points: List[DSEPoint]
    pareto: List[DSEPoint]

    def best(self) -> Optional[DSEPoint]:
        return min(self.pareto,
                   key=lambda p: p.weighted_resource + p.latency_overhead,
                   default=None)

    def table(self) -> str:
        hdr = (f"{'storage':<10}{'depth':>6}{'dump%':>7}{'probes':>8}"
               f"{'state_B':>9}{'xeqns':>7}{'dram_B':>8}{'bw_MBps':>9}"
               f"{'lat_ovh':>9}  pareto")
        lines = [hdr]
        ps = {id(p) for p in self.pareto}
        for p in self.points:
            lines.append(
                f"{p.storage:<10}{p.depth:>6}{p.offload_ratio * 100:>6.0f}%"
                f"{p.n_probes:>8}{p.state_bytes:>9}{p.extra_eqns:>7}"
                f"{p.dram_bytes:>8}{p.dram_bandwidth_bps / 1e6:>9.3f}"
                f"{p.latency_overhead * 100:>8.2f}%"
                f"  {'*' if id(p) in ps else ''}")
        return "\n".join(lines)


def _timeit(f, *args, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = f(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def run_dse(fn: Callable, args: Sequence[Any],
            base_cfg: ProbeConfig = ProbeConfig(),
            storages: Sequence[str] = ("registers", "hybrid", "bram"),
            offload_ratios: Sequence[float] = (0.0, 0.25, 0.5, 0.75),
            resource_weights: Tuple[float, float] = (1.0, 1.0),
            repeats: int = 3) -> DSEResult:
    from repro.core.overhead import measure_overhead

    base_jit = jax.jit(fn)
    base_jit(*args)                       # compile
    t_base = _timeit(base_jit, *args, repeats=repeats)
    base_eqns = None

    pf = probe(fn, base_cfg)              # shared trace across the sweep
    pf.trace(*args)

    points: List[DSEPoint] = []
    for storage in storages:
        depth = STORAGE_DEPTH[storage]
        for ratio in offload_ratios:
            cfg = base_cfg.replace(buffer_depth=depth, offload=ratio)
            pf.retarget(cfg)
            pf.sink.reset()
            out, rec = pf(*args)          # compile + run
            t_inst = _timeit(pf, *args, repeats=repeats)
            span = decode_record(jax.device_get(rec))["cycle"]
            span_s = max(span / CLOCK_HZ, 1e-12)
            ov = measure_overhead(fn, args, cfg)
            if base_eqns is None:
                base_eqns = ov["base_eqns"]
            sbytes = state_bytes(pf.assignment.n, depth)
            wres = (resource_weights[0] * sbytes / 1024.0 +
                    resource_weights[1] * ov["extra_eqns"] /
                    max(ov["base_eqns"], 1))
            points.append(DSEPoint(
                storage=storage, depth=depth, offload_ratio=ratio,
                n_probes=pf.assignment.n, state_bytes=sbytes,
                extra_eqns=ov["extra_eqns"],
                dram_bytes=pf.sink.bytes_received,
                dram_bandwidth_bps=pf.sink.bytes_received / span_s,
                latency_overhead=max(t_inst / max(t_base, 1e-12) - 1.0, 0.0),
                weighted_resource=wres))
    pareto = [p for p in points
              if not any(o.dominates(p) for o in points)]
    return DSEResult(points=points, pareto=pareto)


# ===================================================================
# Kernel-configuration autotuning (probe-guided, budget-constrained)
# ===================================================================

@dataclass
class SearchSpace:
    """Declarative candidate space for one kernel.

    ``axes`` maps axis name -> allowed values; candidates are the
    cartesian product filtered through ``is_valid``. ``bind(config)``
    returns a callable taking ``args`` (example inputs at the shapes
    being tuned) that executes the kernel under that config.
    ``default`` is the untuned baseline the leaderboard compares
    against.
    """
    kernel_id: str
    axes: Dict[str, Tuple[Any, ...]]
    bind: Callable[[Dict[str, Any]], Callable]
    args: Tuple[Any, ...]
    default: Dict[str, Any]
    is_valid: Optional[Callable[[Dict[str, Any]], bool]] = None

    def candidates(self) -> List[Dict[str, Any]]:
        names = sorted(self.axes)
        out = []
        for combo in itertools.product(*(self.axes[n] for n in names)):
            cfg = dict(zip(names, combo))
            if self.is_valid is None or self.is_valid(cfg):
                out.append(cfg)
        return out


@dataclass
class Trial:
    """One candidate's journey through the engine."""
    config: Dict[str, Any]
    resources: Optional[KernelResources] = None
    fingerprint: str = ""
    pruned: Optional[str] = None          # reason, when statically rejected
    cycles_per_step: Optional[float] = None
    steps: int = 0                        # largest rung this trial ran at
    cache_hits: int = 0
    measurements: int = 0
    is_default: bool = False
    # grid-step calibration (``DSEEngine.measure_tiles``): per-tile
    # cycles from the kernel-probed counters vs the cost model's static
    # per-tile estimate; residual = static − measured (positive = the
    # model over-prices tiles, e.g. causal skips it cannot see).
    # tile_dma is the per-step block-DMA term, identical in both, so
    # the calibration ratio is taken over the body term alone.
    tile_static: Optional[float] = None
    tile_measured: Optional[float] = None
    tile_residual: Optional[float] = None
    tile_dma: Optional[float] = None

    @property
    def measured(self) -> bool:
        return self.cycles_per_step is not None


@dataclass
class TuneResult:
    kernel_id: str
    trials: List[Trial]
    best: Optional[Trial]
    default: Optional[Trial]
    n_candidates: int
    n_pruned: int
    n_measurements: int                   # ProbeSession runs performed
    n_cache_hits: int
    measured_steps: int                   # total steps across measurements
    wall_s: float
    device: str = ""

    @property
    def speedup(self) -> float:
        """Default cycles/step over best cycles/step (>1 = tuned wins)."""
        if (self.best is None or self.default is None
                or not self.default.measured or not self.best.measured):
            return 1.0
        return self.default.cycles_per_step / max(self.best.cycles_per_step,
                                                  1e-12)

    def leaderboard(self, top: int = 10) -> str:
        from repro.core import report as report_mod
        return report_mod.dse_leaderboard(self, top=top)

    def to_dict(self) -> Dict[str, Any]:
        def trial(t: Optional[Trial]):
            if t is None:
                return None
            return {"config": t.config, "pruned": t.pruned,
                    "cycles_per_step": t.cycles_per_step, "steps": t.steps,
                    "cache_hits": t.cache_hits,
                    "measurements": t.measurements,
                    "is_default": t.is_default,
                    "tile_residual": t.tile_residual}
        return {
            "kernel": self.kernel_id, "device": self.device,
            "n_candidates": self.n_candidates, "n_pruned": self.n_pruned,
            "n_measurements": self.n_measurements,
            "n_cache_hits": self.n_cache_hits,
            "measured_steps": self.measured_steps,
            "speedup": round(self.speedup, 4),
            "best": trial(self.best), "default": trial(self.default),
            "trials": [trial(t) for t in self.trials],
        }


class DSEEngine:
    """Probe-guided autotuner for Pallas kernel configurations.

    ``tune()`` runs enumerate -> static-prune -> successive-halving
    measurement -> cache, and returns a :class:`TuneResult`. The
    baseline (``space.default``) is always measured alongside the
    survivors so the leaderboard's speedup is honest.

    Successive halving: every surviving candidate runs ``r0`` probed
    steps; the best ``1/eta`` fraction advances with ``eta``x the steps,
    until one remains or ``max_steps`` is reached. All measurements go
    through the :class:`EvalCache`, so a warm re-run performs zero new
    measurements.
    """

    def __init__(self, space: SearchSpace, *,
                 budget: Optional[DeviceBudget] = DeviceBudget(),
                 cache: Optional[EvalCache] = None,
                 cache_dir: Optional[str] = None,
                 cycle_source: str = "model",
                 r0: int = 1, eta: int = 2, max_steps: int = 4,
                 static_prune_ratio: Optional[float] = None):
        if r0 < 1 or eta < 2 or max_steps < r0:
            raise ValueError(f"bad halving schedule r0={r0} eta={eta} "
                             f"max_steps={max_steps}")
        self.space = space
        self.budget = budget
        self.cache = cache if cache is not None else EvalCache(cache_dir)
        self.cycle_source = cycle_source
        self.r0, self.eta, self.max_steps = r0, eta, max_steps
        self.static_prune_ratio = static_prune_ratio
        self.device = device_kind()
        # kernel body names observed by measure_tiles (calibrate targets)
        self._tile_kernels: set = set()
        # run accounting (reset per tune())
        self.n_measurements = 0
        self.n_cache_hits = 0
        self.measured_steps = 0

    # -- stage 1+2: enumerate & statically analyze ----------------------
    def analyze(self, config: Dict[str, Any]) -> Trial:
        """Trace one candidate; attach its IR hash and the cost-model
        resource footprint (no execution)."""
        fn = self.space.bind(config)
        closed = jax.make_jaxpr(fn)(*self.space.args)
        fp = fingerprint_closed(closed)
        res = jaxpr_kernel_resources(closed.jaxpr)
        return Trial(config=dict(config), resources=res, fingerprint=fp)

    def prune(self, trials: Sequence[Trial]) -> List[Trial]:
        """Static rejection against the device budget; optionally also
        drop candidates whose cost-model estimate exceeds
        ``static_prune_ratio`` x the best static estimate. Hard budget
        checks can never discard a config that actually fits the device,
        so the measured-best always survives default pruning."""
        alive = []
        for t in trials:
            if self.budget is not None and t.resources is not None:
                v = self.budget.violations(t.resources)
                if v:
                    t.pruned = "; ".join(v)
                    continue
            alive.append(t)
        if self.static_prune_ratio is not None and alive:
            floor = min(t.resources.static_cycles for t in alive
                        if t.resources is not None)
            kept = []
            for t in alive:
                if (t.resources is not None and floor > 0 and
                        t.resources.static_cycles >
                        self.static_prune_ratio * floor):
                    t.pruned = (f"static {t.resources.static_cycles} cyc > "
                                f"{self.static_prune_ratio:g}x floor {floor}")
                else:
                    kept.append(t)
            alive = kept
        return alive

    # -- stage 3: probed measurement ------------------------------------
    def _measure(self, config: Dict[str, Any], steps: int) -> float:
        """Run ``steps`` probed steps of the candidate under a
        ``ProbeSession``; returns mean cycles/step from the session's
        device span counter."""
        from repro.core.streaming import ProbeSession
        fn = self.space.bind(config)
        cfg = ProbeConfig(targets=("",), max_probes=4, buffer_depth=2,
                          cycle_source=self.cycle_source)
        with ProbeSession(fn, cfg, window_steps=steps + 1) as s:
            for _ in range(steps):
                jax.block_until_ready(s.step(*self.space.args))
            snap = s.snapshot()
        self.n_measurements += 1
        self.measured_steps += steps
        return snap.span / max(steps, 1)

    def _eval_fingerprint(self, t: Trial) -> str:
        """Trial fingerprint extended with the installed kernel-
        calibration state: measured cycles come from the model clock,
        whose pallas pricing is scaled by ``costmodel``'s process-
        global calibration — cycles measured under different
        calibrations must never collide under one cache key. The
        uncalibrated state leaves the key unchanged (existing caches
        stay warm)."""
        from repro.core.costmodel import kernel_calibration_state
        state = kernel_calibration_state()
        if not state:
            return t.fingerprint
        tag = ";".join(f"{k}={v:.6f}" for k, v in state)
        return f"{t.fingerprint}|calib[{tag}]"

    def evaluate(self, t: Trial, steps: int) -> float:
        """Cache-through evaluation at a rung of ``steps`` steps."""
        fp = self._eval_fingerprint(t)
        hit = self.cache.get(self.space.kernel_id, t.config, fp,
                             self.device, min_steps=steps)
        if hit is not None:
            t.cache_hits += 1
            self.n_cache_hits += 1
            t.cycles_per_step = float(hit["cycles_per_step"])
            t.steps = max(t.steps, int(hit["steps"]))
            return t.cycles_per_step
        cps = self._measure(t.config, steps)
        t.measurements += 1
        t.cycles_per_step = cps
        t.steps = steps
        self.cache.put(self.space.kernel_id, t.config, fp,
                       self.device, cycles_per_step=cps, steps=steps)
        return cps

    # -- grid-step calibration (measured per-tile cycles) ----------------
    def measure_tiles(self, t: Trial) -> Trial:
        """Probe the candidate with intra-kernel grid-step counters and
        record per-tile cycles on the trial.

        ``tile_measured`` is the mean measured cycles per grid step
        (sum of grid-probe totals over grid-probe calls — exact model-
        clock counters that see ``pl.when`` skips), ``tile_static`` the
        cost model's flat per-step estimate, ``tile_residual`` their
        gap. The kernel body names observed are remembered as
        ``calibrate()`` targets."""
        from repro.core.pragma import probe as _probe

        from repro.core import costmodel as _cm
        from repro.core import kernelprobe as _kp

        fn = self.space.bind(t.config)
        cfg = ProbeConfig(targets=("",), max_probes=16, buffer_depth=2,
                          cycle_source=self.cycle_source,
                          kernel_probes=("*",), inline="off_all")
        pf = _probe(fn, cfg)
        # retarget onto the kernel subtrees so deep grid probes can
        # never be crowded out of the probe budget by shallow wrapper
        # scopes (selection is preorder/shallow-first)
        h = pf.trace(*self.space.args)
        kpaths = tuple(n.path for n in h.root.walk() if n.kind == "kernel")
        if not kpaths:
            raise ValueError(
                f"measure_tiles({t.config}): the bound function has no "
                f"statically-gridded pallas kernels to probe")
        pf.retarget(cfg.replace(targets=kpaths))
        _, rec = pf(*self.space.args)
        dec = decode_record(jax.device_get(rec))
        grid_total = grid_calls = 0
        for i, path in enumerate(pf.probe_paths()):
            if path.endswith("/grid"):
                grid_total += int(dec["totals"][i])
                grid_calls += int(dec["calls"][i])
                # <scope>/kernel/<name>#i/grid -> <name>
                self._tile_kernels.add(
                    path.rsplit("/", 2)[-2].split("#")[0])
        if grid_calls:
            t.tile_measured = grid_total / grid_calls
        # per-step DMA term (shared by measured and static tiles): from
        # the traced pallas equations, steps-weighted across kernels
        dma_total = steps_total = 0
        for pe in _cm._walk_pallas_eqns(pf.hierarchy.closed_jaxpr.jaxpr):
            g = _kp.static_grid(pe)
            if g is None:
                continue
            s = int(np.prod(g))
            dma_total += _kp.dma_cycles(pe) * s
            steps_total += s
        if steps_total:
            t.tile_dma = dma_total / steps_total
        if t.resources is not None and t.resources.grid_steps:
            t.tile_static = (t.resources.static_cycles /
                             t.resources.grid_steps)
        if t.tile_measured is not None and t.tile_static is not None:
            t.tile_residual = t.tile_static - t.tile_measured
        return t

    def calibration(self, trials: Optional[Sequence[Trial]] = None
                    ) -> Optional[float]:
        """measured/static ratio of the per-tile BODY term (the DMA
        term is identical on both sides and is not scaled by
        ``costmodel._pallas_cost``, so it is subtracted before the
        ratio — otherwise calibration could not converge even on the
        trial it was measured from)."""
        ratios = []
        for t in (trials if trials is not None else []):
            if t.tile_measured is None or not t.tile_static:
                continue
            dma = t.tile_dma or 0.0
            body_static = t.tile_static - dma
            if body_static <= 0:
                continue
            ratios.append(max(t.tile_measured - dma, 0.0) / body_static)
        if not ratios:
            return None
        return float(np.mean(ratios))

    def calibrate(self, trials: Sequence[Trial]) -> Optional[float]:
        """Install the measured per-tile ratio into the cost model's
        block-level body term (``costmodel.set_kernel_calibration``)
        for every kernel body seen by ``measure_tiles``. Subsequent
        ``analyze()`` / prune passes then price tiles with measured
        grid-step cycles. Returns the scale (None without tile data);
        undo with ``costmodel.clear_kernel_calibration()``."""
        from repro.core import costmodel as _cm

        scale = self.calibration(trials)
        if scale is None:
            return None
        for kname in sorted(self._tile_kernels):
            _cm.set_kernel_calibration(kname, scale)
        return scale

    def successive_halving(self, trials: List[Trial]) -> Optional[Trial]:
        active = list(trials)
        r = self.r0
        while active:
            for t in active:
                self.evaluate(t, r)
            active.sort(key=lambda t: t.cycles_per_step)
            if len(active) == 1 or r >= self.max_steps:
                return active[0]
            keep = max(1, math.ceil(len(active) / self.eta))
            active = active[:keep]
            r = min(r * self.eta, self.max_steps)
        return None

    # -- the whole loop --------------------------------------------------
    def tune(self) -> TuneResult:
        self.n_measurements = self.n_cache_hits = self.measured_steps = 0
        t0 = time.perf_counter()
        configs = self.space.candidates()
        trials = [self.analyze(c) for c in configs]
        default_trial = None
        for t in trials:
            if t.config == self.space.default:
                t.is_default = True
                default_trial = t
        survivors = self.prune(trials)
        best = self.successive_halving(survivors)
        # always measure the baseline (even if pruned / not in the space),
        # at the SAME rung as the finalist — comparing a 1-step sample
        # against a max_steps mean is meaningless under wallclock noise
        if default_trial is None:
            default_trial = self.analyze(self.space.default)
            default_trial.is_default = True
            trials.append(default_trial)
        base_steps = best.steps if (best is not None and best.measured) \
            else self.r0
        if not default_trial.measured or default_trial.steps < base_steps:
            self.evaluate(default_trial, base_steps)
        if best is None or (default_trial.measured and best.measured and
                            default_trial.cycles_per_step
                            <= best.cycles_per_step):
            best = default_trial
        if best is not None and best.measured:
            shape = str([(tuple(getattr(a, "shape", ())),
                          str(getattr(a, "dtype", "?")))
                         for a in jax.tree_util.tree_leaves(self.space.args)])
            self.cache.set_winner(self.space.kernel_id, self.device,
                                  best.config,
                                  cycles_per_step=best.cycles_per_step,
                                  shape=shape)
        return TuneResult(
            kernel_id=self.space.kernel_id, trials=trials, best=best,
            default=default_trial, n_candidates=len(configs),
            n_pruned=sum(1 for t in trials if t.pruned is not None),
            n_measurements=self.n_measurements,
            n_cache_hits=self.n_cache_hits,
            measured_steps=self.measured_steps,
            wall_s=time.perf_counter() - t0, device=self.device)
