"""Deterministic TPU-v5e analytical cycle model (the profiler's "clock").

Every jaxpr equation gets an integer cycle cost derived from its FLOPs
and memory traffic against the hardware constants below. The SAME static
table drives (a) the in-device instrumented counters, (b) the oracle
("ILA") interpreter, and (c) the static ("C-synth") estimate — which is
what makes the paper's 100%-accuracy experiment exact here, and keeps the
profiler output dimensionally consistent with §Roofline.

On a real TPU deployment the ``CycleSource`` seam in ``instrument.py``
swaps this model clock for hardware timestamps; nothing else changes.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

# -------------------------------------------------- hardware constants
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s/link (reference; used by roofline)
CLOCK_HZ = 940e6                  # TPU v5e core clock
VMEM_BYTES = 16 * 2 ** 20         # on-chip vector memory per core

FLOPS_PER_CYCLE = PEAK_FLOPS_BF16 / CLOCK_HZ      # ~209574
HBM_BYTES_PER_CYCLE = HBM_BW / CLOCK_HZ           # ~871
ICI_BYTES_PER_CYCLE = ICI_BW / CLOCK_HZ           # ~53

# transcendental elementwise ops cost more VPU work per element
_TRANSCENDENTAL = {
    "exp", "log", "log1p", "expm1", "tanh", "logistic", "erf", "erfc",
    "erf_inv", "sin", "cos", "tan", "pow", "rsqrt", "sqrt", "cbrt",
    "atan2", "digamma", "lgamma",
}
_NO_FLOP = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "slice",
    "dynamic_slice", "dynamic_update_slice", "concatenate", "pad", "rev",
    "gather", "scatter", "scatter-add", "convert_element_type",
    "bitcast_convert_type", "copy", "iota", "stop_gradient", "select_n",
    "split",
}
_COLLECTIVES = {
    "psum", "all_gather", "reduce_scatter", "all_to_all", "ppermute",
    "psum_scatter", "pmax", "pmin", "pbroadcast",
}

# Mesh axis sizes for the collective term. When set (``collective_axis_
# sizes``), collective eqns are costed with the ring-model *wire bytes*
# for their actual group size — per-device cycles then respond to the
# mesh shape, which is what mesh-aware probing and communication-aware
# DSE tune against. When unset (the default), the legacy operand-bytes
# approximation keeps single-device numbers (and committed benchmark
# baselines) unchanged.
_AXIS_SIZES: contextvars.ContextVar[Optional[Dict[str, int]]] = \
    contextvars.ContextVar("repro_collective_axis_sizes", default=None)


@contextlib.contextmanager
def collective_axis_sizes(sizes: Optional[Dict[str, int]]):
    """Cost collectives against these mesh axis sizes (ring wire model)."""
    tok = _AXIS_SIZES.set(dict(sizes) if sizes is not None else None)
    try:
        yield
    finally:
        _AXIS_SIZES.reset(tok)


def current_axis_sizes() -> Optional[Dict[str, int]]:
    return _AXIS_SIZES.get()


def collective_comm_bytes(name: str, axes: Tuple[str, ...],
                          in_bytes: int, out_bytes: int) -> int:
    """Comm bytes of one collective execution under the CURRENT axis-
    size context: ring wire model when mesh axis sizes are in context,
    legacy operand-bytes fallback otherwise. Decomposed (primitive name
    + mesh axes, not a live eqn) so captured trace artifacts can
    re-price collectives for a different mesh without re-tracing."""
    sizes = _AXIS_SIZES.get()
    if sizes is None:
        return in_bytes
    from repro.launch.collectives import PRIMITIVE_KINDS, ring_wire_bytes
    kind = PRIMITIVE_KINDS.get(name)
    if kind is None:
        return in_bytes
    g = 1
    for a in axes:
        g *= int(sizes.get(a, 1))
    return int(math.ceil(ring_wire_bytes(kind, out_bytes, g)))


def collective_eqn_axes(eqn) -> Tuple[str, ...]:
    """Named mesh axes a collective eqn reduces/permutes over."""
    from repro.launch.collectives import collective_axes
    return tuple(str(a) for a in collective_axes(eqn))


def _collective_comm_bytes(eqn, in_bytes: int, out_bytes: int) -> int:
    if _AXIS_SIZES.get() is None:
        return in_bytes
    return collective_comm_bytes(eqn.primitive.name,
                                 collective_eqn_axes(eqn),
                                 in_bytes, out_bytes)


def roofline_cycles(flops: int, total_bytes: int, comm_bytes: int) -> int:
    """The model's single cycle formula: the max of the compute, memory
    and interconnect terms, never below one cycle."""
    return max(1, int(math.ceil(max(flops / FLOPS_PER_CYCLE,
                                    total_bytes / HBM_BYTES_PER_CYCLE,
                                    comm_bytes / ICI_BYTES_PER_CYCLE))))


def collective_cycles(name: str, axes: Tuple[str, ...], *, flops: int,
                      in_bytes: int, out_bytes: int) -> int:
    """Cycles of one collective execution under the current
    ``collective_axis_sizes`` context — the re-pricing seam used by
    ``tracesim`` (identical arithmetic to ``eqn_cost``'s collective
    branch)."""
    comm = collective_comm_bytes(name, axes, in_bytes, out_bytes)
    return roofline_cycles(flops, in_bytes + out_bytes, comm)


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _aval_size(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


@dataclass(frozen=True)
class EqnCost:
    flops: int
    bytes: int
    comm_bytes: int
    cycles: int


def _dot_flops(eqn) -> int:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = (v.aval for v in eqn.invars[:2])
    batch = int(np.prod([lhs.shape[i] for i in lb])) if lb else 1
    contract = int(np.prod([lhs.shape[i] for i in lc])) if lc else 1
    m = int(np.prod([s for i, s in enumerate(lhs.shape)
                     if i not in lc and i not in lb])) or 1
    n = int(np.prod([s for i, s in enumerate(rhs.shape)
                     if i not in rc and i not in rb])) or 1
    return 2 * batch * m * n * contract


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    out_elems = _aval_size(out)
    # per output element: 2 * prod(kernel spatial) * in_features
    k = int(np.prod(rhs.shape[:-1])) if rhs.shape else 1
    return 2 * out_elems * k


def pallas_kernel_name(eqn) -> str:
    """Human name of a ``pallas_call``'s kernel body ('flash_kernel')."""
    nsi = eqn.params.get("name_and_src_info")
    name = getattr(nsi, "name", None) or eqn.params.get("name") or "kernel"
    return str(name).lstrip("_")


# Measured calibration of the block-level body term. The DSE engine
# (``dse.DSEEngine.calibrate``) divides probed grid-step cycles by the
# static estimate and installs the ratio here; ``_pallas_cost`` then
# prices the body with measured — not modeled — per-tile cycles (the
# causal-skip fraction the static max-branch estimate cannot see).
# Process-global like the tuned-config registry (kernels.tuning).
_KERNEL_CALIB: Dict[str, float] = {}


def set_kernel_calibration(kernel: str, scale: float) -> None:
    """Scale the static body-cycle term of kernel ``kernel`` (the
    pallas body name, e.g. 'flash_kernel') by measured/static."""
    _KERNEL_CALIB[kernel] = float(scale)


def clear_kernel_calibration(kernel: Optional[str] = None) -> None:
    if kernel is None:
        _KERNEL_CALIB.clear()
    else:
        _KERNEL_CALIB.pop(kernel, None)


def kernel_calibration(kernel: str) -> float:
    return _KERNEL_CALIB.get(kernel, 1.0)


def kernel_calibration_state() -> Tuple[Tuple[str, float], ...]:
    """The full installed-calibration state, canonically ordered —
    measurement cache keys include it so calibrated and uncalibrated
    model-clock cycles never collide under one key."""
    return tuple(sorted(_KERNEL_CALIB.items()))


def pallas_dma_cycles(eqn) -> int:
    """Per-grid-step HBM<->VMEM block DMA cycles of a ``pallas_call``.
    The single definition shared by ``_pallas_cost`` and the grid-step
    walker (``kernelprobe``) — the calibration ratio subtracts this
    term from both sides, so the two must never drift."""
    body = _as_jaxpr(eqn.params["jaxpr"])
    block_bytes = sum(_aval_bytes(v.aval) for v in body.invars)
    return int(math.ceil(block_bytes / HBM_BYTES_PER_CYCLE))


def _pallas_grid_steps(eqn) -> int:
    gm = eqn.params.get("grid_mapping")
    grid = getattr(gm, "grid", ()) or ()
    steps = 1
    for g in grid:
        try:
            steps *= int(g)
        except (TypeError, ValueError):     # dynamic grid dim: count once
            pass
    return max(steps, 1)


def flat_pallas_cycles(kernel: str, body_cycles: int, dma_cycles: int,
                       steps: int) -> int:
    """Flat cycles of a whole ``pallas_call`` from its decomposed terms:
    per-step body cycles (scaled by the installed calibration for this
    kernel body name) plus the per-step block DMA, times the grid size.
    The single definition shared by ``_pallas_cost`` (live pricing) and
    ``tracesim.price`` (artifact re-pricing) — the two must never
    drift, or calibrated sweep filtering would rank candidates by a
    different clock than the one the finalists are measured on."""
    scale = kernel_calibration(kernel)
    if scale != 1.0:
        body_cycles = max(1, int(round(body_cycles * scale)))
    return steps * max(1, body_cycles + dma_cycles)


def _pallas_cost(eqn) -> EqnCost:
    """Cost of a ``pallas_call``: per-grid-step kernel-body cycles (the
    body jaxpr's avals are BLOCK-shaped, so tile/pipeline choices change
    this) times the grid size, plus the per-step HBM<->VMEM block DMA.
    This is what makes probed cycle counts sensitive to kernel configs —
    the signal the DSE engine tunes against."""
    body = _as_jaxpr(eqn.params["jaxpr"])
    steps = _pallas_grid_steps(eqn)
    body_cycles = static_jaxpr_cycles(body)
    flops, bytes_ = jaxpr_flat_flops_bytes(body)
    # block DMA per grid step: every kernel operand ref (input blocks,
    # output blocks, scratch) is VMEM-resident; HBM-backed blocks move
    # across the memory system once per step
    block_bytes = sum(_aval_bytes(v.aval) for v in body.invars)
    dma_cycles = pallas_dma_cycles(eqn)
    cycles = flat_pallas_cycles(pallas_kernel_name(eqn), body_cycles,
                                dma_cycles, steps)
    return EqnCost(flops=steps * flops,
                   bytes=steps * (bytes_ + block_bytes),
                   comm_bytes=0, cycles=cycles)


def eqn_cost(eqn) -> EqnCost:
    """Flat cost of one first-order equation (control flow handled by
    the interpreters, which recurse)."""
    name = eqn.primitive.name
    if name == "pallas_call":
        try:
            return _pallas_cost(eqn)
        except (KeyError, AttributeError, TypeError):
            pass          # unknown pallas param layout: generic fallback
    in_bytes = sum(_aval_bytes(v.aval) for v in eqn.invars
                   if hasattr(v, "aval"))
    out_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)
    total_bytes = in_bytes + out_bytes
    comm = 0
    if name == "dot_general":
        flops = _dot_flops(eqn)
    elif name == "ragged_dot":
        # rows each hit one expert: 2 * rows * K * N
        lhs = eqn.invars[0].aval
        rhs = eqn.invars[1].aval
        flops = 2 * lhs.shape[0] * lhs.shape[1] * rhs.shape[-1]
    elif name in ("conv_general_dilated",):
        flops = _conv_flops(eqn)
    elif name in _COLLECTIVES:
        comm = _collective_comm_bytes(eqn, in_bytes, out_bytes)
        flops = _aval_size(eqn.outvars[0].aval) if eqn.outvars else 0
    elif name in _NO_FLOP:
        flops = 0
    elif name in _TRANSCENDENTAL:
        flops = 8 * max((_aval_size(v.aval) for v in eqn.outvars), default=0)
    elif name in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                  "reduce_and", "reduce_or", "argmax", "argmin",
                  "cumsum", "cumlogsumexp", "cummax", "cumprod"):
        flops = max((_aval_size(v.aval) for v in eqn.invars
                     if hasattr(v, "aval")), default=0)
    elif name in ("sort", "top_k"):
        n = max((_aval_size(v.aval) for v in eqn.invars
                 if hasattr(v, "aval")), default=1)
        flops = int(n * max(1, math.log2(max(n, 2))))
    else:
        # generic elementwise fallback
        flops = max((_aval_size(v.aval) for v in eqn.outvars), default=0)
    cycles = roofline_cycles(int(flops), total_bytes, comm)
    return EqnCost(flops=int(flops), bytes=int(total_bytes),
                   comm_bytes=int(comm), cycles=cycles)


# ---------------------------------------------- recursive static costs

_SUBJAXPR_PRIMS = {"scan", "while", "cond", "pjit", "jit", "custom_jvp_call",
                   "custom_vjp_call", "remat", "checkpoint", "shard_map",
                   "custom_vjp_call_jaxpr", "closed_call", "core_call",
                   "remat2"}


def _sub_jaxprs(eqn):
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in eqn.params:
            yield eqn.params[key]
    if "cond_jaxpr" in eqn.params:
        yield eqn.params["cond_jaxpr"]
        yield eqn.params["body_jaxpr"]
    if "branches" in eqn.params:
        yield from eqn.params["branches"]


def _as_jaxpr(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


def static_eqn_cycles(eqn) -> int:
    """Cycles of one eqn for a SINGLE execution, recursing into control
    flow with static trip counts (while counted as one iteration — the
    'C-synth ?' case; only runtime counters know the truth)."""
    name = eqn.primitive.name
    if name == "scan":
        body = static_jaxpr_cycles(_as_jaxpr(eqn.params["jaxpr"]))
        return body * int(eqn.params["length"])
    if name == "while":
        return (static_jaxpr_cycles(_as_jaxpr(eqn.params["cond_jaxpr"])) * 2 +
                static_jaxpr_cycles(_as_jaxpr(eqn.params["body_jaxpr"])))
    if name == "cond":
        return max(static_jaxpr_cycles(_as_jaxpr(b))
                   for b in eqn.params["branches"])
    if name in _SUBJAXPR_PRIMS:
        subs = list(_sub_jaxprs(eqn))
        if subs:
            return sum(static_jaxpr_cycles(_as_jaxpr(s)) for s in subs[:1])
    return eqn_cost(eqn).cycles


def static_jaxpr_cycles(jaxpr) -> int:
    return sum(static_eqn_cycles(e) for e in jaxpr.eqns)


def jaxpr_flat_flops_bytes(jaxpr) -> "Tuple[int, int]":
    """(flops, bytes) for one execution of a jaxpr, recursing into
    control flow like ``static_eqn_cycles`` (scan x trip count, cond as
    the widest branch, while as a single iteration)."""
    flops = bytes_ = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            f, b = jaxpr_flat_flops_bytes(_as_jaxpr(eqn.params["jaxpr"]))
            n = int(eqn.params["length"])
            flops += f * n
            bytes_ += b * n
        elif name == "while":
            f, b = jaxpr_flat_flops_bytes(_as_jaxpr(eqn.params["body_jaxpr"]))
            flops += f
            bytes_ += b
        elif name == "cond":
            branch = [jaxpr_flat_flops_bytes(_as_jaxpr(br))
                      for br in eqn.params["branches"]]
            flops += max(f for f, _ in branch)
            bytes_ += max(b for _, b in branch)
        elif name in _SUBJAXPR_PRIMS:
            subs = list(_sub_jaxprs(eqn))
            if subs:
                f, b = jaxpr_flat_flops_bytes(_as_jaxpr(subs[0]))
                flops += f
                bytes_ += b
        else:
            c = eqn_cost(eqn)
            flops += c.flops
            bytes_ += c.bytes
    return flops, bytes_


# ------------------------------------------- kernel resource footprints

@dataclass(frozen=True)
class KernelResources:
    """Static footprint of one candidate kernel configuration — the
    analogue of the paper's post-synthesis LUT/FF/BRAM report."""
    vmem_bytes: int           # per-grid-step working set (double-buffered)
    hbm_bytes: int            # modeled total memory traffic
    flops: int
    grid_steps: int
    static_cycles: int        # cost-model cycle estimate for the call


@dataclass(frozen=True)
class DeviceBudget:
    """Hard per-candidate resource ceilings (LUT/FF/BRAM analogue:
    VMEM bytes, HBM traffic, FLOPs). ``None`` disables a ceiling."""
    vmem_bytes: Optional[int] = VMEM_BYTES
    hbm_bytes: Optional[int] = None
    flops: Optional[int] = None

    def violations(self, r: KernelResources) -> Tuple[str, ...]:
        out = []
        if self.vmem_bytes is not None and r.vmem_bytes > self.vmem_bytes:
            out.append(f"vmem {r.vmem_bytes}B > {self.vmem_bytes}B")
        if self.hbm_bytes is not None and r.hbm_bytes > self.hbm_bytes:
            out.append(f"hbm {r.hbm_bytes}B > {self.hbm_bytes}B")
        if self.flops is not None and r.flops > self.flops:
            out.append(f"flops {r.flops} > {self.flops}")
        return tuple(out)

    def fits(self, r: KernelResources) -> bool:
        return not self.violations(r)


def _walk_pallas_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from _walk_pallas_eqns(_as_jaxpr(sub))


def _ref_vmem_bytes(aval) -> int:
    """VMEM working-set contribution of one kernel operand ref: HBM-
    backed blocks (memory space unset) are double-buffered by the
    HBM->VMEM pipeline; explicit VMEM scratch is single-buffered."""
    single = getattr(aval, "memory_space", None) is not None
    return (1 if single else 2) * _aval_bytes(aval)


def jaxpr_kernel_resources(jaxpr) -> KernelResources:
    """Aggregate Pallas-kernel footprint of a traced program: VMEM is
    the max per-grid-step working set over all ``pallas_call``s (input/
    output blocks double-buffered for the HBM->VMEM pipeline, scratch
    single-buffered), traffic/FLOPs/cycles summed."""
    vmem = hbm = flops = steps = cycles = 0
    for eqn in _walk_pallas_eqns(jaxpr):
        try:
            body = _as_jaxpr(eqn.params["jaxpr"])
            n = _pallas_grid_steps(eqn)
            block = sum(_ref_vmem_bytes(v.aval) for v in body.invars)
            c = _pallas_cost(eqn)
        except (KeyError, AttributeError, TypeError):
            continue      # unknown pallas param layout (see eqn_cost)
        vmem = max(vmem, block)
        hbm += c.bytes
        flops += c.flops
        steps += n
        cycles += c.cycles
    return KernelResources(vmem_bytes=vmem, hbm_bytes=hbm, flops=flops,
                           grid_steps=steps, static_cycles=cycles)


def jaxpr_has_dynamic_cycles(jaxpr) -> bool:
    """True if cycle count depends on runtime values (while / cond)."""
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in ("while", "cond"):
            return True
        for sub in _sub_jaxprs(eqn):
            if jaxpr_has_dynamic_cycles(_as_jaxpr(sub)):
                return True
    return False
