"""repro.core — RealProbe (Kim & Hao, 2025) adapted to TPU/JAX.

The paper's contribution as a composable module:

    from repro.core import probe, ProbeConfig
    pf = probe(train_step, ProbeConfig(targets=("loss/layers",)))
    out, record = pf(params, batch)        # non-intrusive, jitted
    print(pf.report(record).timeline())

Stages (paper Fig 3):
  1 pragma     pragma.probe / ProbeConfig
  2 extraction hierarchy.extract (C-to-RTL mapping table)
  3 IP gen     instrument.Instrumenter (+ counters, buffer spill)
  4 system     incremental (trace cache, decoupled base executable)
  5 results    report (timeline / table / bump chart), oracle (ILA check)
Plus: overhead (analytical resource model), dse (automated DSE).
"""
from repro.core.pragma import ProbeConfig, ProbedFunction, probe
from repro.core.hierarchy import Hierarchy, extract
from repro.core.oracle import KernelOracle, Oracle
from repro.core.report import (Report, bump_chart, kernel_grid_heat,
                               kernel_grid_table, streaming_bump_chart,
                               streaming_table)
from repro.core.dse import (run_dse, run_sweep, DSEResult, DSEEngine,
                            SearchSpace, SweepResult, Trial, TuneResult)
from repro.core.costmodel import DeviceBudget, KernelResources
from repro.core.incremental import (measure_incremental, EvalCache,
                                    FileLock, device_kind,
                                    lowered_fingerprint)
from repro.core.tracesim import (KernelTrace, TraceEntry, TraceStore,
                                 capture, capture_entry, price)
from repro.core.overhead import OverheadModel, measure_overhead, adapt_allocation
from repro.core.streaming import (ProbeSession, StreamAggregator,
                                  StreamingSink, StreamSnapshot)
from repro.core.meshprobe import (CycleRecord, MeshProbedFunction,
                                  MeshProbeSession, MeshReport, ShardOracle,
                                  decode_mesh_record, mesh_probe)

__all__ = [
    "probe", "ProbeConfig", "ProbedFunction", "Hierarchy", "extract",
    "Oracle", "Report", "bump_chart", "run_dse", "DSEResult",
    "measure_incremental", "OverheadModel", "measure_overhead",
    "adapt_allocation",
    # probe-guided kernel autotuning (DSE engine + incremental eval cache)
    "DSEEngine", "SearchSpace", "Trial", "TuneResult", "DeviceBudget",
    "KernelResources", "EvalCache", "device_kind", "lowered_fingerprint",
    # streaming telemetry (continuous in-production sessions)
    "ProbeSession", "StreamAggregator", "StreamingSink", "StreamSnapshot",
    "streaming_table", "streaming_bump_chart",
    # mesh-aware probing (per-device cycle records over sharded programs)
    "mesh_probe", "MeshProbedFunction", "MeshProbeSession", "MeshReport",
    "CycleRecord", "ShardOracle", "decode_mesh_record",
    # intra-kernel grid-step probing (ProbeConfig.kernel_probes)
    "KernelOracle", "kernel_grid_table", "kernel_grid_heat",
    # trace-once cycle simulator + sweep farm
    "KernelTrace", "TraceEntry", "TraceStore", "capture", "capture_entry",
    "price", "run_sweep", "SweepResult", "FileLock",
]
