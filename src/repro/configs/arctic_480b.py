"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) expert d_ff=4864
vocab=32000, MoE 128 experts top-2 + Arctic's dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]

HBM note: at 480B params a fp32 master + fp32 moments cannot fit a
16 GB/chip pod slice; this config keeps bf16 params + int8 blockwise AdamW moments + bf16 grad accumulation
(documented in DESIGN.md §Distribution design).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    head_dim=128,
    padded_heads=64,          # 56 q-heads padded to 4/shard on TP=16
    moe=MoEConfig(num_experts=128, top_k=2, dense_residual=True,
                  residual_d_ff=4864),
    train_microbatches=8,
    grad_accum_dtype="bfloat16",
    moment_dtype="int8",
    param_dtype="bfloat16",
)

