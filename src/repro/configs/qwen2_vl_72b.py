"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings; M-RoPE position ids (3, B, S) are inputs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    pos_emb="mrope",
    mrope_sections=(16, 24, 24),   # temporal/h/w rotary sections (sum=64)
    frontend="vision",
    use_bias=True,                 # qwen2 uses qkv biases
    train_microbatches=8,          # 72B on 16GB/chip: activation lever
    moment_dtype="int8",           # rowwise-quantized AdamW moments
    grad_accum_dtype="bfloat16",
)
