"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input-shape suites are ``ShapeConfig``s. Configs are frozen
dataclasses so they can be hashed into jit/static caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # Arctic-style dense residual MLP running in parallel with the MoE FFN.
    dense_residual: bool = False
    # d_ff of the dense residual branch (defaults to the expert d_ff).
    residual_d_ff: int = 0
    # capacity factor used by the EP (shard_map) dispatch path
    capacity_factor: float = 1.25
    # "capacity": sort + scatter into (E, C, d) blocks + dense batched
    #             GEMMs (GShard-style, token-dropping) — default
    # "ragged":  dropless sort + grouped GEMM (custom sparse VJP); for
    #            megablox-class backends
    impl: str = "capacity"
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""
    d_state: int
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    conv_kernel: int = 4
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | ssm | hybrid | moe | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    # TP head padding (Megatron-style): q-head dim padded to a multiple of
    # the model axis so attention shards; pad-head outputs are hard-masked
    # to zero (exact semantics, dead weights). 0 = no padding.
    padded_heads: int = 0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one weight-shared attention block applied after every
    # ``shared_attn_every`` SSM layers.
    shared_attn_every: int = 0
    # positional encoding: "rope" | "mrope" | "none"
    pos_emb: str = "rope"
    rope_theta: float = 10000.0
    # M-RoPE (qwen2-vl): head_dim split into (temporal, h, w) sections.
    mrope_sections: Tuple[int, int, int] = (0, 0, 0)
    # modality frontend stub: "none" (token ids) | "audio" | "vision"
    frontend: str = "none"
    norm_eps: float = 1e-5
    use_bias: bool = False
    tie_embeddings: bool = False
    # attention: "xla_flash" (chunked running-softmax einsum path, used for
    # lowering/dry-run) | "pallas" (TPU kernel; validated in interpret mode)
    attn_impl: str = "xla_flash"
    attn_chunk: int = 1024       # kv chunk for the xla_flash path
    # training numerics
    param_dtype: str = "float32"     # master copy dtype
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"    # AdamW m/v dtype (bf16 for arctic-480b)
    remat: str = "full"              # full | dots | none
    loss_chunk: int = 2048           # vocab-parallel chunked xent seq chunk
    # schedule: "wsd" (minicpm) | "cosine"
    schedule: str = "cosine"
    # gradient-accumulation microbatches for the production train step
    # (memory lever for the biggest archs)
    train_microbatches: int = 1
    grad_accum_dtype: str = "float32"   # bf16 for arctic (HBM floor)
    # prefill batch-chunking: fwd-only activation lever for 32k prompts
    prefill_microbatches: int = 1
    # serving
    kv_cache_dtype: str = "bfloat16"
    # which shape suites this arch supports (long_500k only sub-quadratic)
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def resolved_padded_heads(self) -> int:
        return self.padded_heads or self.num_heads

    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 256 (Megatron-style) so the
        vocab-parallel embedding/logits shard evenly on any TP<=256;
        pad logits are masked to -inf in the loss/sampler."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape suite (arch-independent)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class TrainConfig:
    """Run-level knobs (optimizer, schedule, batching, fault tolerance)."""
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    stable_ratio: float = 0.8        # WSD: fraction of post-warmup in stable
    grad_clip: float = 1.0
    microbatches: int = 1            # grad accumulation (pipeline-friendly)
    # cross-pod gradient compression ("none" | "int8_ef")
    grad_compression: str = "none"
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    keep_checkpoints: int = 3
