"""Architecture registry + reduced ("smoke") config derivation.

``get_config(name)`` returns the full assigned configuration;
``smoke_config(name)`` returns a structurally-identical but tiny variant
(few layers, narrow width, tiny vocab, few experts) that runs a real
forward/train step on CPU in the test suite. Full configs are only ever
lowered/compiled abstractly via the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig

from repro.configs import (  # noqa: E402  (import order is the registry)
    minicpm_2b,
    granite_3_2b,
    tinyllama_1_1b,
    command_r_35b,
    mamba2_370m,
    musicgen_large,
    zamba2_2_7b,
    qwen2_vl_72b,
    arctic_480b,
    granite_moe_1b_a400m,
)

_MODULES = (
    minicpm_2b, granite_3_2b, tinyllama_1_1b, command_r_35b, mamba2_370m,
    musicgen_large, zamba2_2_7b, qwen2_vl_72b, arctic_480b,
    granite_moe_1b_a400m,
)

CONFIGS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def list_archs() -> List[str]:
    return list(CONFIGS)


def get_config(name: str) -> ModelConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}") from None


def supported_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """The assigned shape cells for one architecture.

    ``long_500k`` requires sub-quadratic attention and is skipped (with a
    DESIGN.md note) for pure full-attention architectures.
    """
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue
        out.append(s)
    return out


def all_cells() -> List[tuple]:
    """Every (arch, shape) dry-run cell, including explicit skips."""
    cells = []
    for name, cfg in CONFIGS.items():
        for s in SHAPES.values():
            skip = s.name == "long_500k" and not cfg.supports_long_context
            cells.append((name, s.name, skip))
    return cells


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(name)
    kw: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=64,
        vocab_size=257,           # deliberately odd (uneven-sharding path)
        loss_chunk=32,
        attn_chunk=64,
    )
    if cfg.num_heads:
        kw.update(num_heads=4, num_kv_heads=min(4, max(1, cfg.num_kv_heads // 8)),
                  head_dim=16, d_ff=128)
    else:
        kw.update(num_heads=0, num_kv_heads=0, d_ff=0)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(2, cfg.moe.top_k),
            residual_d_ff=32 if cfg.moe.dense_residual else 0)
        kw["d_ff"] = 32
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=8,
                                        chunk_size=16)
    if cfg.shared_attn_every:
        kw["shared_attn_every"] = 1
        kw["num_layers"] = 2
    if cfg.pos_emb == "mrope":
        kw["mrope_sections"] = (2, 3, 3)   # sums to head_dim/2 = 8
    return cfg.replace(**kw)
