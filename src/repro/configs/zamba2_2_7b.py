"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + weight-SHARED attention
block applied every 6 SSM layers (Zamba2 style). [arXiv:2411.15242; hf]

Sub-quadratic (only the shared-attn KV grows) => runs long_500k.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,               # 54 Mamba2 layers
    d_model=2560,
    num_heads=32,                # shared attention block (MHA: kv=32)
    num_kv_heads=32,
    d_ff=10240,                  # shared block MLP
    vocab_size=32000,
    head_dim=80,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256, conv_kernel=4),
    shared_attn_every=6,         # 9 invocations of the shared block
    supports_long_context=True,
)
