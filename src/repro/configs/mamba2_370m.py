"""mamba2-370m [ssm] — 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128 (SSD, state-space duality). [arXiv:2405.21060; unverified]

d_inner = expand*d_model = 2048; head_dim 64 => 32 SSD heads.
Attention-free => runs the long_500k shape (O(1)/token decode).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256, conv_kernel=4),
    pos_emb="none",
    supports_long_context=True,
)
