from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig, TrainConfig,
    SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
)

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "TrainConfig",
    "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
