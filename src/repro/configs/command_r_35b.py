"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000, no-bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    head_dim=128,
    use_bias=False,
    tie_embeddings=True,       # command-r ties embeddings
    train_microbatches=4,      # 35B on 16GB/chip: activation lever
)
