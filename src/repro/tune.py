"""``python -m repro.tune`` — entry point shim for the autotuning CLI.

The implementation lives in :mod:`repro.launch.tune`.
"""
import sys

from repro.launch.tune import main

if __name__ == "__main__":
    sys.exit(main())
