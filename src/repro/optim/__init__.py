from repro.optim import adamw, compression, schedule
from repro.optim.adamw import AdamWState, clip_by_global_norm, global_norm

__all__ = ["adamw", "compression", "schedule", "AdamWState",
           "clip_by_global_norm", "global_norm"]
