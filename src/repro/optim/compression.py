"""int8 error-feedback gradient compression for the cross-pod all-reduce.

At 512+ chips the pod-to-pod (DCI) hop is the thinnest link in the
gradient all-reduce. This implements 1-bit-Adam-style error feedback
[arXiv:2102.02888-adjacent]: quantize (grad + residual) to int8 with a
per-tensor scale before the cross-pod reduce, keep the quantization error
as residual state for the next step. Convergence-safe (error feedback is
unbiased over time), 4x less DCI traffic than f32 / 2x less than bf16.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_residual(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, residual) -> Tuple[Any, Any, Any]:
    """Returns (int8 payload, scales, new_residual_partial). The residual
    update completes in ``decompress_combine`` once the payload is known
    (compression error = pre-quant value - dequantized value)."""

    def q(g, r):
        g32 = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q8 = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_r = g32 - q8.astype(jnp.float32) * scale
        return q8, scale, new_r

    flat, tdef = jax.tree_util.tree_flatten(grads)
    rflat = tdef.flatten_up_to(residual)
    out = [q(g, r) for g, r in zip(flat, rflat)]
    payload = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    scales = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_res = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return payload, scales, new_res


def decompress(payload, scales, dtype=jnp.float32) -> Any:
    return jax.tree_util.tree_map(
        lambda q, s: (q.astype(jnp.float32) * s).astype(dtype),
        payload, scales)
