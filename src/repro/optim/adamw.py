"""AdamW with dtype policies + global-norm clipping.

Moments can be stored in bf16 (``ModelConfig.moment_dtype``) — required
for arctic-480b to fit 16 GB/chip HBM (math is always f32; storage
rounds). Master params follow ``param_dtype``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray              # int32 scalar
    mu: Any                        # pytree like params (arrays or QTensor)
    nu: Any


def init(params, moment_dtype: str = "float32") -> AdamWState:
    if moment_dtype == "int8":
        from repro.optim.quantized import zeros_like_q
        zeros = zeros_like_q
    else:
        md = jnp.dtype(moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, md)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def _load_moment(m):
    from repro.optim.quantized import QTensor, dequantize
    if isinstance(m, QTensor):
        return dequantize(m)
    return m.astype(jnp.float32)


def _store_moment(m32, like):
    from repro.optim.quantized import QTensor, quantize
    if isinstance(like, QTensor):
        return quantize(m32)
    return m32.astype(like.dtype)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def update(params, grads, state: AdamWState, cfg: TrainConfig,
           schedule: Callable) -> Tuple[Any, AdamWState, Dict[str, Any]]:
    with jax.named_scope("clip"):
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(step)
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * _load_moment(m) + (1 - b1) * g32
        v32 = b2 * _load_moment(v) + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p32)
        return (p_new.astype(p.dtype), _store_moment(m32, m),
                _store_moment(v32, v))

    # Big (layer-stacked) leaves are updated under a lax.scan over the
    # leading dim: the optimizer is bandwidth-bound and elementwise, and
    # bounding its f32 working set to one layer slice per leaf keeps peak
    # HBM flat (measured 27 GiB of concurrent f32 update temporaries on
    # arctic-480b without this).
    SCAN_THRESHOLD_BYTES = 128 * 2**20

    def upd_maybe_scanned(p, g, m, v):
        if p.ndim >= 2 and p.nbytes > SCAN_THRESHOLD_BYTES:
            def body(_, xs):
                return None, upd(*xs)
            _, (pn, mn, vn) = jax.lax.scan(body, None, (p, g, m, v))
            return pn, mn, vn
        return upd(p, g, m, v)

    from repro.optim.quantized import QTensor
    is_leaf = lambda x: isinstance(x, QTensor)
    with jax.named_scope("adamw"):
        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = jax.tree_util.tree_leaves(state.mu, is_leaf=is_leaf)
        flat_v = jax.tree_util.tree_leaves(state.nu, is_leaf=is_leaf)
        out = [upd_maybe_scanned(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), {
        "lr": lr, "grad_norm": gnorm}
