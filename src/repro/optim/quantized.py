"""Row-wise int8 quantized optimizer state (bitsandbytes-flavored).

For arctic-480b on a 16 GB/chip v5e pod, fp32 (even bf16) AdamW moments
do not fit: params 0.96 TB + bf16 moments 1.92 TB + grads vs 4 TB
aggregate HBM. 8-bit moments with per-row f32 scales cut the moment
bytes ~2x vs bf16 with negligible quality impact [arXiv:2110.02861].

Quantization is one reduce + elementwise ops along the last dim — no
padding or reshapes — so GSPMD sharding propagates through it untouched
(a blockwise variant with pad-to-256 reshapes was measured to force
replication of every optimizer tensor on the 16x16 mesh).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jnp.ndarray        # int8, shape = orig shape
    s: jnp.ndarray        # f32 scales, shape = (*orig[:-1], 1)


def quantize(x) -> QTensor:
    """x -> rowwise int8 along the last dim."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q, s=scale)


def dequantize(qt: QTensor) -> jnp.ndarray:
    return qt.q.astype(jnp.float32) * qt.s


def zeros_like_q(p) -> QTensor:
    sshape = (p.shape[:-1] + (1,)) if p.ndim else (1,)
    return QTensor(q=jnp.zeros(p.shape, jnp.int8),
                   s=jnp.zeros(sshape, jnp.float32))
