"""LR schedules: WSD (MiniCPM's warmup-stable-decay) and cosine."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def wsd(step, cfg: TrainConfig, peak_lr: float):
    """Warmup-Stable-Decay [arXiv:2404.06395]: linear warmup, long stable
    plateau, then exponential-style decay to 10% of peak."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.float32(cfg.warmup_steps)
    total = jnp.float32(cfg.total_steps)
    stable_end = warm + (total - warm) * cfg.stable_ratio
    warmup_lr = peak_lr * step / jnp.maximum(warm, 1.0)
    decay_frac = (step - stable_end) / jnp.maximum(total - stable_end, 1.0)
    decay_lr = peak_lr * jnp.power(0.1, jnp.clip(decay_frac, 0.0, 1.0))
    return jnp.where(step < warm, warmup_lr,
                     jnp.where(step < stable_end, peak_lr, decay_lr))


def cosine(step, cfg: TrainConfig, peak_lr: float):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.float32(cfg.warmup_steps)
    total = jnp.float32(cfg.total_steps)
    warmup_lr = peak_lr * step / jnp.maximum(warm, 1.0)
    frac = jnp.clip((step - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
    cos_lr = 0.1 * peak_lr + 0.9 * peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warm, warmup_lr, cos_lr)


def make_schedule(name: str, cfg: TrainConfig):
    fn = {"wsd": wsd, "cosine": cosine}[name]
    return lambda step: fn(step, cfg, cfg.learning_rate)
