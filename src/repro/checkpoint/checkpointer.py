"""Atomic, async, sharded checkpointing with elastic restore.

Fault-tolerance contract for 1000+ node jobs:

- **Atomicity**: writes go to ``step_XXXX.tmp/`` then ``os.rename`` to
  ``step_XXXX/`` — a crash mid-write never corrupts the latest restore
  point; ``latest()`` only ever sees committed directories.
- **Async**: serialization runs on a background thread off the training
  critical path (``wait()`` joins before the next save or at exit).
- **Sharded**: each host writes only its param shards (here: the
  process-local arrays; on multihost each process saves
  ``addressable_shards``) plus one manifest with step, mesh shape and
  data-pipeline state for exactly-once data accounting.
- **Elastic restore**: ``restore`` takes the *target* sharding tree —
  arrays are re-laid-out with ``jax.device_put``, so a job can restart on
  a different mesh (fewer/more data-parallel replicas after node loss).
- **Retention**: keeps the newest ``keep`` checkpoints, deletes older.
- **Preemption hook**: ``install_sigterm_handler`` saves on SIGTERM.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        """Snapshot ``tree`` (pytree of arrays) at ``step``."""
        self.wait()
        # snapshot to host memory synchronously (cheap), serialize async
        flat, treedef = jax.tree_util.tree_flatten(tree)
        host = [np.asarray(x) for x in flat]
        meta = {
            "step": int(step),
            "treedef": str(treedef),
            "n_arrays": len(host),
            "extra": extra or {},
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
        }

        def work():
            try:
                tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
                final = os.path.join(self.dir, f"step_{step:08d}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"),
                         **{f"a{i}": a for i, a in enumerate(host)})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)          # atomic commit
                self._gc()
            except BaseException as e:          # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError("async checkpoint failed") from e

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any,
                shardings: Optional[Any] = None) -> Any:
        """Restore into the structure of ``target_tree``; if ``shardings``
        (matching pytree of Sharding) is given, arrays are placed with
        that layout — the elastic-restore path."""
        self.wait()
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            meta = json.load(f)
        npz = np.load(os.path.join(path, "arrays.npz"))
        host = [npz[f"a{i}"] for i in range(meta["n_arrays"])]
        flat_t, treedef = jax.tree_util.tree_flatten(target_tree)
        if len(flat_t) != len(host):
            raise ValueError(
                f"checkpoint has {len(host)} arrays, target {len(flat_t)}")
        flat_s = (treedef.flatten_up_to(shardings)
                  if shardings is not None else [None] * len(host))
        out = []
        for a, t, s in zip(host, flat_t, flat_s):
            arr = a.astype(t.dtype) if hasattr(t, "dtype") else a
            out.append(jax.device_put(arr, s) if s is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), meta["extra"]

    # ------------------------------------------------------- preemption
    def install_sigterm_handler(self, save_fn: Callable[[], None]):
        """Run ``save_fn`` (then re-raise default behavior) on SIGTERM —
        the preemption notice on cloud TPU fleets."""
        def handler(signum, frame):
            save_fn()
            self.wait()
            signal.signal(signal.SIGTERM, signal.SIG_DFL)
            os.kill(os.getpid(), signal.SIGTERM)
        signal.signal(signal.SIGTERM, handler)
