#!/usr/bin/env python
"""Golden-record conformance suite: canonical decoded probe records.

The profiler now carries three exactness contracts (oracle equality,
streaming aggregation, mesh records) plus the intra-kernel grid-step
layer. This tool pins the *decoded record itself* — every counter,
ring slot and probe path of a fixed-seed probe run — as key-sorted
JSON under ``tests/golden/``; ``tests/test_golden.py`` asserts exact
equality on every run, so any change to probe selection, cost-model
pricing, event ordering or record layout shows up as a reviewable
JSON diff instead of a silent drift.

Records are produced by the deterministic model clock, so they are
machine-independent — but they DO depend on the traced jaxpr and
therefore on the jax version. Each file records the version it was
generated with (the CI baseline pin); the test skips on other
versions (the nightly pinned matrix keeps it exercised).

Usage:
    PYTHONPATH=src python tools/regen_golden.py            # rewrite all
    PYTHONPATH=src python tools/regen_golden.py --diff     # preview only
    PYTHONPATH=src python tools/regen_golden.py --case flash_grid
"""
from __future__ import annotations

import argparse
import difflib
import json
import os
import sys
from typing import Any, Callable, Dict, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN_DIR = os.path.join(REPO, "tests", "golden")


# ------------------------------------------------------------- cases

def _case_flash_grid():
    """Causal flash attention, kernel grid-step probes, full offload."""
    import jax
    import jax.numpy as jnp
    from repro.core import ProbeConfig
    from repro.kernels import flash_attention as fa

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (1, 2, 128, 32), jnp.float32)
    k = jax.random.normal(k2, (1, 2, 128, 32), jnp.float32)
    v = jax.random.normal(k3, (1, 2, 128, 32), jnp.float32)

    def fn(q, k, v):
        with jax.named_scope("attn"):
            return fa.flash_attention(q, k, v, causal=True, block_q=64,
                                      block_k=64, pipeline=2,
                                      interpret=True)

    return fn, (q, k, v), ProbeConfig(inline="off_all",
                                      kernel_probes=("*",),
                                      offload=1.0, buffer_depth=4)


def _case_ssd_grid():
    """SSD chunk scan, kernel grid-step probes."""
    import jax
    import jax.numpy as jnp
    from repro.core import ProbeConfig
    from repro.kernels import ssd_scan as ssdk

    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (1, 2, 128, 16), jnp.float32) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (1, 2, 128))) * 0.3
    b = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.float32) * 0.5
    c = jax.random.normal(ks[3], (1, 2, 128, 32), jnp.float32) * 0.5

    def fn(x, a, b, c):
        with jax.named_scope("ssd"):
            return ssdk.ssd_scan(x, a, b, c, chunk=32, pipeline=2,
                                 interpret=True)

    return fn, (x, a, b, c), ProbeConfig(inline="off_all",
                                         kernel_probes=("*",),
                                         offload=1.0, buffer_depth=4)


def _case_transformer_step():
    """Tiny transformer forward step (scope/loop probes, no kernels)."""
    import jax
    from repro.configs.registry import smoke_config
    from repro.core import ProbeConfig
    from repro.models import Model

    cfg = smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(k, (2, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.fold_in(k, 1),
                                          (2, 32), 0, cfg.vocab_size)}

    def fn(params, batch):
        return model.loss_fn(params, batch)

    return fn, (params, batch), ProbeConfig(max_probes=24)


CASES: Dict[str, Callable[[], Tuple[Callable, tuple, Any]]] = {
    "flash_grid": _case_flash_grid,
    "ssd_grid": _case_ssd_grid,
    "transformer_step": _case_transformer_step,
}

# the serving-engine case has its own document shape (per-request phase
# bills instead of a single probe record), so it dispatches separately
ENGINE_CASE = "engine_serve"


def run_engine_case() -> Dict[str, Any]:
    """Mixed request trace through the continuous-batching engine with
    probing on: pins every decoded token, per-request per-phase cycle
    bill, page sharing, bucket histogram, and the zero-retrace count."""
    import jax
    import numpy as np
    from repro.configs.registry import smoke_config
    from repro.engine import EngineConfig, InferenceEngine
    from repro.models import Model

    cfg = smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab_size, 16).tolist()
    prompts = [prefix + rng.integers(0, cfg.vocab_size, 5).tolist(),
               rng.integers(0, cfg.vocab_size, 7).tolist(),
               prefix + rng.integers(0, cfg.vocab_size, 9).tolist(),
               rng.integers(0, cfg.vocab_size, 13).tolist()]
    max_new = [5, 3, 4, 6]
    eng = InferenceEngine(model, params, EngineConfig(
        page_size=16, pool_pages=32, max_pages=4, buckets=(1, 2, 4),
        probe=True, interpret=True))
    for p, m in zip(prompts, max_new):
        eng.submit(p, m)
    done = eng.run()
    st = eng.stats()
    eng.drain()
    balanced = eng.table.balanced()
    eng.close()
    return {
        "case": ENGINE_CASE, "jax": jax.__version__,
        "requests": [{
            "rid": r.rid, "prompt_len": len(r.prompt),
            "out_tokens": list(r.out_tokens),
            "phase_cycles": dict(r.phase_cycles),
            "decode_batches": list(r.decode_batches),
            "shared_pages": r.shared_pages,
        } for r in done],
        "phases": st["phases"],
        "stats": {
            "retraces": st["retraces"],
            "pages_peak": st["pages_peak"],
            "prefix_hits": st["prefix_hits"],
            "prefix_misses": st["prefix_misses"],
            "buckets": {str(k): v for k, v in st["buckets"].items()},
            "steps_traced": st["steps_traced"],
            "balanced_after_drain": balanced,
        },
    }


# ------------------------------------------- per-arch registry cases

def arch_slug(arch: str) -> str:
    """Golden filename stem for one registry architecture."""
    return "arch_" + arch.replace("-", "_").replace(".", "_")


def list_arch_cases() -> Dict[str, str]:
    """slug -> registry arch name, for every ``registry.list_archs()``
    entry (each gets one golden file holding a probed train step record
    AND a probed serve decode record)."""
    from repro.configs import registry
    return {arch_slug(a): a for a in registry.list_archs()}


def _arch_train(arch: str):
    """Probed ``build_train_step`` over the arch's smoke config —
    deterministic params/opt/batch, same idiom as the system tests."""
    import jax
    from repro.configs.base import TrainConfig
    from repro.configs.registry import smoke_config
    from repro.core import ProbeConfig
    from repro.distributed.steps import build_train_step
    from repro.models import Model
    from repro.optim import adamw

    import jax.numpy as jnp

    cfg = smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params, cfg.moment_dtype)
    B, S = 2, 32
    k = jax.random.PRNGKey(0)
    if cfg.frontend != "none":
        from repro.models.frontends import synth_frontend_batch
        batch = dict(synth_frontend_batch(cfg, B, S, jnp.bfloat16, k))
    else:
        batch = {"tokens": jax.random.randint(k, (B, S), 0,
                                              cfg.vocab_size)}
    batch["labels"] = jax.random.randint(jax.random.fold_in(k, 1),
                                         (B, S), 0, cfg.vocab_size)
    step = build_train_step(model, TrainConfig(total_steps=10,
                                               warmup_steps=1))
    return step, (params, opt, batch), ProbeConfig(max_probes=24)


def _arch_serve(arch: str):
    """Probed single-token ``decode_step`` against a fresh cache."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import smoke_config
    from repro.core import ProbeConfig
    from repro.models import Model

    cfg = smoke_config(arch)
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    B = 2
    shape = ShapeConfig("t", seq_len=64, global_batch=B, kind="decode")
    cache = m.init_cache(shape)
    if cfg.frontend != "none":
        from repro.models.frontends import synth_frontend_batch
        fb = synth_frontend_batch(cfg, B, 1, jnp.bfloat16, key)
        batch = {"embeds": fb["embeds"], "pos": jnp.int32(3)}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32),
                 "pos": jnp.int32(3)}
    return m.decode_step, (params, cache, batch), \
        ProbeConfig(max_probes=24)


# ------------------------------------------------- canonical encoding

def _record_doc(pf, rec) -> Dict[str, Any]:
    """Canonical decoded-record sub-document for one probe run."""
    import jax
    from repro.core.instrument import decode_record

    dec = decode_record(jax.device_get(rec))
    return {
        "paths": list(pf.probe_paths()),
        "record": {
            "cycle": int(dec["cycle"]),
            "starts": [int(x) for x in dec["starts"]],
            "ends": [int(x) for x in dec["ends"]],
            "totals": [int(x) for x in dec["totals"]],
            "calls": [int(x) for x in dec["calls"]],
            "ring": dec["ring"].astype(int).tolist(),
        },
        "offloaded": {
            str(pid): [[int(s), int(e)] for s, e in pf.sink.records(pid)]
            for pid in range(pf.assignment.n) if pf.assignment.spill[pid]
        },
    }


def run_case(name: str) -> Dict[str, Any]:
    """Execute one case with a FRESH ProbedFunction and return its
    canonical golden document (plain JSON types, key-sorted on dump)."""
    import jax
    from repro.core import probe

    if name == ENGINE_CASE:
        return run_engine_case()
    arch_cases = list_arch_cases()
    if name in arch_cases:
        return run_arch_case(arch_cases[name])
    fn, args, cfg = CASES[name]()
    pf = probe(fn, cfg)
    _, rec = pf(*args)
    return {"case": name, "jax": jax.__version__, **_record_doc(pf, rec)}


def run_arch_case(arch: str) -> Dict[str, Any]:
    """One registry arch: probed train-step + serve-decode records."""
    import jax
    from repro.core import probe

    doc: Dict[str, Any] = {"case": arch_slug(arch), "arch": arch,
                           "jax": jax.__version__}
    for phase, builder in (("train", _arch_train), ("serve", _arch_serve)):
        fn, args, cfg = builder(arch)
        pf = probe(fn, cfg)
        _, rec = pf(*args)
        doc[phase] = _record_doc(pf, rec)
    return doc


def encode(doc: Dict[str, Any]) -> str:
    return json.dumps(doc, sort_keys=True, indent=1) + "\n"


def golden_path(name: str) -> str:
    return os.path.join(GOLDEN_DIR, f"{name}.json")


def main(argv=None) -> int:
    all_names = sorted(CASES) + [ENGINE_CASE] + sorted(list_arch_cases())
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--case", choices=all_names, default=None,
                    help="regenerate one case (default: all)")
    ap.add_argument("--diff", action="store_true",
                    help="preview the diff against the committed records "
                         "without writing anything")
    args = ap.parse_args(argv)
    names = [args.case] if args.case else all_names
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    changed = 0
    for name in names:
        new = encode(run_case(name))
        path = golden_path(name)
        old = ""
        if os.path.exists(path):
            with open(path) as f:
                old = f.read()
        if new == old:
            print(f"{name}: unchanged")
            continue
        changed += 1
        if args.diff:
            sys.stdout.writelines(difflib.unified_diff(
                old.splitlines(keepends=True), new.splitlines(keepends=True),
                fromfile=f"a/tests/golden/{name}.json",
                tofile=f"b/tests/golden/{name}.json"))
        else:
            with open(path, "w") as f:
                f.write(new)
            print(f"{name}: {'re' if old else ''}written -> {path}")
    if args.diff and changed:
        print(f"\n{changed} case(s) differ (run without --diff to rewrite)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
