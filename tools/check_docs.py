#!/usr/bin/env python
"""Docs CI: execute every fenced code example and check every link.

Code snippets in README/docs rot silently — an API rename leaves the
quickstart broken until a user pastes it. This checker makes the docs
executable:

- every ````` ```python ````` block is executed (blocks in one file
  share a namespace, so a later block may use names the quickstart
  defined — exactly how a reader runs them top to bottom);
- every ````` ```pycon ````` block (``>>>`` prompts) runs under
  ``doctest``, outputs compared;
- a block preceded by an HTML comment containing ``docs-check: skip``
  is extracted but not executed (for illustrative pseudo-code);
- ``bash``/``text``/untagged fences are ignored;
- every relative markdown link target must exist on disk (http links
  are left alone — CI must stay offline-deterministic).

Run locally:  PYTHONPATH=src python tools/check_docs.py
Multi-device snippets rely on the forced 8-device host platform set
below, so run it in a fresh interpreter (not after importing jax).
"""
from __future__ import annotations

import doctest
import glob
import os
import re
import sys
import traceback
from typing import List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FENCE_RE = re.compile(r"^```([\w-]*)\s*$")
_SKIP_RE = re.compile(r"docs-check:\s*skip")
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def extract_blocks(text: str) -> List[Tuple[str, int, str, bool]]:
    """(lang, first_line_no, code, skip) for every fenced block."""
    blocks = []
    lines = text.splitlines()
    i, skip_next = 0, False
    while i < len(lines):
        if _SKIP_RE.search(lines[i]) and lines[i].lstrip().startswith("<!--"):
            skip_next = True
            i += 1
            continue
        m = _FENCE_RE.match(lines[i])
        if m:
            lang, start = m.group(1), i + 2
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            blocks.append((lang, start, "\n".join(body), skip_next))
            skip_next = False
        elif lines[i].strip():
            skip_next = False
        i += 1
    return blocks


def run_python(path: str, blocks) -> List[str]:
    errors = []
    ns: dict = {"__name__": "__docs__", "__file__": path}
    for lang, line, code, skip in blocks:
        if skip:
            continue
        if lang == "python":
            try:
                exec(compile(code, f"{path}:{line}", "exec"), ns)
            except Exception:
                tb = traceback.format_exc(limit=3)
                errors.append(f"{path}:{line}: python block failed\n{tb}")
        elif lang == "pycon":
            runner = doctest.DocTestRunner(verbose=False,
                                           optionflags=doctest.ELLIPSIS)
            test = doctest.DocTestParser().get_doctest(
                code, dict(ns), f"{path}:{line}", path, line)
            out: List[str] = []
            runner.run(test, out=out.append)
            if runner.failures:
                errors.append(f"{path}:{line}: pycon block failed\n"
                              + "".join(out))
            ns.update(test.globs)
    return errors


def check_links(path: str, text: str) -> List[str]:
    errors = []
    base = os.path.dirname(path)
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if not rel:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, rel))):
            errors.append(f"{path}: dead link -> {target}")
    return errors


def main(argv=None) -> int:
    # must land before any jax import (device count is fixed at backend
    # init) — which is why these side effects live here, not at module
    # import: the test suite imports this module without running main
    if "jax" in sys.modules:
        print("warning: jax already imported; multi-device snippets may "
              "see the wrong device count", file=sys.stderr)
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    sys.path.insert(0, os.path.join(REPO, "src"))
    files = [os.path.join(REPO, "README.md")] + \
        sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    failures: List[str] = []
    for path in files:
        with open(path) as f:
            text = f.read()
        blocks = extract_blocks(text)
        n_run = sum(1 for lang, _, _, skip in blocks
                    if lang in ("python", "pycon") and not skip)
        failures += run_python(path, blocks)
        failures += check_links(path, text)
        print(f"[docs] {os.path.relpath(path, REPO)}: "
              f"{len(blocks)} fenced blocks, {n_run} executed")
    if failures:
        for f in failures:
            print(f"FAIL  {f}", file=sys.stderr)
        print(f"# {len(failures)} docs failure(s)", file=sys.stderr)
        return 1
    print("# docs check: all snippets executed, no dead links")
    return 0


if __name__ == "__main__":
    sys.exit(main())
